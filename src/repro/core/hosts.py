"""Multi-host distributed data plane — the placement registry promoted from
partitioning an SSD namespace to partitioning a *cluster*.

PRs 1-8 built every subsystem single-host: shards were SSD queues hanging
off one PCIe root.  This module re-reads the same shard vocabulary at host
granularity — each shard is now a HOST described by a `HostLinkSpec` (its
interconnect into the fabric plus its local `SSDSpec`) — so the max-over-
shards burst pricing, straggler/imbalance telemetry, fault injection, and
the PR 7/8 feedback machinery all carry over unchanged.  What changes is
the cost model: a feature row served by a host other than the one that
*requested* it transits that host's link, and
`StorageTimeline.price_host_burst` (core/storage_sim.py) composes the
remote host's local storage drain with that link-transit term.

Who requests a row?  The cluster runs one trainer per host (DistDGL-style
data-parallel sampling): host h samples the frontier expanded from ITS
partition of the adjacency, so feature row u is requested by the host that
owns the edges *into* u.  `requester_hosts` materializes that as a static
per-node table — the majority vote over u's in-neighbors' topology hosts
(ties break to the lowest host index; nodes nothing samples into are
requested where their own adjacency lives).  A storage request is REMOTE
iff its requester differs from the serving shard; remote rows ship as
whole 4 KB lines over the serving host's link (the second level of the
merged-window coalescing: dedup per host first, then line-granular
transfer per host-local queue).

This is what makes placement quality measurable: under `hash` striping
~(k-1)/k of every batch is remote no matter how the topology is placed,
while a min-cut placement (`metis-lite`, core/sharding.py) co-partitioned
with the adjacency (`CoPartitionedPlacement` — ONE placement decision
drives both the feature rows and the CSR edge pages of a node) keeps a
node's in-neighbors, hence its requester, on its own host — killing the
double network hop the motivation cites.

`n_hosts=1` degenerates exactly: every requester equals the only shard,
no remote lines exist, the link term is never added, and the plane prices
bit-identically to the single-host plane.  Features and blocks are
bit-identical across ALL host counts and placements — hosts change
pricing and telemetry, never bytes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .storage_sim import IO_BYTES, SSDSpec
from .tiers import ShardedStorageTier

#: Decorrelated 64-bit mix for the *independent* (non-co-partitioned)
#: topology-host assignment — a different odd constant than sharding._FIB
#: so the two namespaces' hash stripes never accidentally align.
_MIX2 = np.uint64(0xC2B2AE3D27D4EB4F)


@dataclasses.dataclass(frozen=True)
class HostLinkSpec:
    """One host of the cluster: its interconnect into the fabric (NIC /
    PCIe peer link / ICI) and its local storage device.  `ssd=None` means
    "inherit the loader's device spec" — `HostShardTier.resolve_hosts`
    fills it in, the same fallback `ShardedStorageTier.resolve_shard_specs`
    gives spec-less shards."""

    name: str
    link_bw: float                    # bytes/s into the fabric
    link_rtt_s: float                 # one remote exchange's round trip
    ssd: SSDSpec | None = None        # local device; None = loader default

    def with_ssd(self, ssd: SSDSpec) -> "HostLinkSpec":
        return dataclasses.replace(self, ssd=ssd)


# Stock interconnects.  100GbE is the default cluster fabric; the RTTs are
# switch-traversal scale (not WAN) — a rack-local training pod.
NIC_100GBE = HostLinkSpec("nic-100gbe", link_bw=12.5e9, link_rtt_s=10e-6)
NIC_400GBE = HostLinkSpec("nic-400gbe", link_bw=50e9, link_rtt_s=5e-6)
TPU_ICI = HostLinkSpec("tpu-ici", link_bw=90e9, link_rtt_s=1.5e-6)


def default_hosts(n_hosts: int, link: HostLinkSpec = NIC_100GBE,
                  ssd: SSDSpec | None = None) -> tuple[HostLinkSpec, ...]:
    """A homogeneous cluster of `n_hosts` copies of `link`, named host0..N."""
    return tuple(
        dataclasses.replace(link, name=f"{link.name}/host{h}", ssd=ssd)
        for h in range(int(n_hosts)))


def independent_hosts(num_nodes: int, n_hosts: int,
                      seed: int = 0) -> np.ndarray:
    """The NON-co-partitioned topology-host assignment: a hash stripe over
    node ids deliberately decorrelated from every feature placement, so
    "independent" means what it says — a node's adjacency host carries no
    information about its feature host.  (int16, like every shard table.)"""
    if n_hosts <= 1:
        return np.zeros(num_nodes, np.int16)
    ids = np.arange(num_nodes, dtype=np.uint64)
    mixed = ((ids + np.uint64(seed) * np.uint64(0x9E3779B9)) * _MIX2) \
        >> np.uint64(40)
    return (mixed % np.uint64(n_hosts)).astype(np.int16)


def requester_hosts(indptr: np.ndarray, indices: np.ndarray,
                    topo_host: np.ndarray, n_hosts: int) -> np.ndarray:
    """Which host requests each node's feature row, (N,) int16.

    One trainer per host samples the frontier expanded from its own
    adjacency partition, so node u's features are fetched by the host
    owning the edges INTO u: the majority vote over u's in-neighbors v of
    `topo_host[v]`.  Ties break toward u's OWN adjacency host when it is
    among the winners (a host sampling its own partition touches its own
    nodes first; any residual tie takes the lowest host index — fully
    deterministic).  Nodes nothing points at (seed-only nodes) are
    requested by their own adjacency's host: seeds expand locally."""
    n = len(indptr) - 1
    topo_host = np.asarray(topo_host)
    if n_hosts <= 1 or len(indices) == 0:
        return topo_host.astype(np.int16).copy()
    outdeg = np.diff(np.asarray(indptr, np.int64))
    owner = np.repeat(np.arange(n, dtype=np.int64), outdeg)
    votes = np.zeros((n, int(n_hosts)), np.int64)
    np.add.at(votes, (np.asarray(indices, np.int64),
                      topo_host[owner].astype(np.int64)), 1)
    req = votes.argmax(axis=1).astype(np.int16)
    own = topo_host.astype(np.int64)
    own_wins = votes[np.arange(n), own] == votes[np.arange(n), req]
    req[own_wins] = own[own_wins].astype(np.int16)
    unsampled = votes.sum(axis=1) == 0
    req[unsampled] = topo_host[unsampled].astype(np.int16)
    return req


def cut_edge_fraction(indptr: np.ndarray, indices: np.ndarray,
                      node_host: np.ndarray) -> float:
    """Fraction of CSR edges whose endpoints live on different hosts — the
    DistDGL cost driver the metis-lite placement minimizes.  Static (a
    function of graph + placement only), so benchmarks can report it
    without running a single batch."""
    indices = np.asarray(indices, np.int64)
    if len(indices) == 0:
        return 0.0
    node_host = np.asarray(node_host)
    outdeg = np.diff(np.asarray(indptr, np.int64))
    owner = np.repeat(np.arange(len(outdeg), dtype=np.int64), outdeg)
    return float(np.mean(node_host[owner] != node_host[indices]))


class CoPartitionedPlacement:
    """ONE placement decision driving BOTH namespaces: a node's feature
    rows and its CSR edge pages land on the same host.

    Wraps any registered placement policy; `shard_of` (the feature
    namespace) answers with the base decision and `topology_host_of` (the
    adjacency namespace) answers with the SAME decision — agreement for
    every node by construction, which is the property the hypothesis suite
    pins.  Edge pages are placed by the owner of their first edge word
    (`page_host_of`), so a node's adjacency pages follow it.

    Attribute access falls through to the base policy, so an adaptive base
    keeps its `plan_rebalance`/`commit` seam and a replicated base its
    replica map — the whole PR 7/8 feedback/fault stack works unchanged
    through this wrapper."""

    def __init__(self, base):
        self.base = base
        self.n_shards = base.n_shards
        self.name = f"co-partitioned({getattr(base, 'name', 'placement')})"

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray:
        return self.base.shard_of(node_ids)

    def topology_host_of(self, node_ids: np.ndarray) -> np.ndarray:
        """The adjacency namespace's host for each node == the feature
        namespace's shard.  This method EXISTING is what marks a placement
        co-partitioned (`HostShardTier` keys off it)."""
        return self.base.shard_of(node_ids)

    def page_host_of(self, indptr: np.ndarray, n_edge_words: int,
                     page_words: int) -> np.ndarray:
        """Host of each 4 KB edge page: the owner node of the page's first
        edge word (pages are node-contiguous in CSR order, so this keeps a
        node's whole adjacency with its features up to page-boundary
        spill)."""
        indptr = np.asarray(indptr, np.int64)
        n_pages = max(1, -(-int(n_edge_words) // int(page_words)))
        first = np.minimum(np.arange(n_pages, dtype=np.int64) * page_words,
                           max(int(n_edge_words) - 1, 0))
        owner = np.searchsorted(indptr, first, side="right") - 1
        owner = np.clip(owner, 0, len(indptr) - 2)
        return np.asarray(self.base.shard_of(owner), np.int16)

    def state_dict(self) -> dict:
        return {"name": self.name, "n_shards": self.n_shards,
                "base": self.base.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        if state.get("name", self.name) != self.name \
                or state.get("n_shards", self.n_shards) != self.n_shards:
            raise ValueError(
                f"co-partitioned placement state {state.get('name')!r}/"
                f"{state.get('n_shards')} does not match {self.name!r}/"
                f"{self.n_shards}")
        self.base.load_state_dict(state["base"])

    def __getattr__(self, attr: str):
        # adaptive seam (table/touches/plan_rebalance/commit), replica map
        # (replicas_of/replica_shards), and policy state fall through
        return getattr(self.base, attr)


class HostShardTier(ShardedStorageTier):
    """The storage backstop partitioned across a CLUSTER: each shard is a
    host (`HostLinkSpec` — interconnect + local SSD) rather than a bare
    SSD queue.  Bytes are unchanged; what this tier adds over
    `ShardedStorageTier` is the *requester* model: a static per-node table
    of which host fetches each row (in-neighbor majority over the
    topology-host assignment, `requester_hosts`), from which `build_plan`
    stamps a per-request remote mask and the merged executor derives the
    per-host remote 4 KB line counts that `StorageTimeline.
    price_host_burst` ships over each host's link.

    `co_partition=True` (default) wraps the placement in
    `CoPartitionedPlacement` — one decision for features AND edge pages;
    False assigns the adjacency by an `independent_hosts` hash stripe, the
    double-network-hop baseline the benchmarks compare against."""

    def __init__(self, features: np.ndarray, placement, hosts=None, *,
                 graph=None, co_partition: bool = True,
                 name: str = "host-storage", seed: int = 0):
        if co_partition and not hasattr(placement, "topology_host_of"):
            placement = CoPartitionedPlacement(placement)
        super().__init__(features, placement, specs=None, name=name)
        n_hosts = placement.n_shards
        if hosts is None:
            hosts = default_hosts(n_hosts)
        elif isinstance(hosts, HostLinkSpec):
            hosts = default_hosts(n_hosts, link=hosts, ssd=hosts.ssd)
        else:
            hosts = tuple(hosts)
        if len(hosts) != n_hosts:
            raise ValueError(
                f"{len(hosts)} host specs for {n_hosts} hosts — pass one "
                "HostLinkSpec per host (or a single spec to replicate)")
        self.hosts = hosts
        self.graph = graph
        self.seed = int(seed)
        self.co_partition = hasattr(placement, "topology_host_of")
        n = len(features)
        if self.co_partition:
            self._topo_host = np.asarray(
                placement.topology_host_of(np.arange(n)), np.int16)
        else:
            self._topo_host = independent_hosts(n, n_hosts, seed)
        if graph is not None:
            self._requester = requester_hosts(
                graph.indptr, graph.indices, self._topo_host, n_hosts)
        else:
            # no adjacency to vote over: each row is requested where its
            # (modelled) adjacency lives — co-partitioned planes see zero
            # remote, independent planes the decorrelated-hash mismatch
            self._requester = self._topo_host.copy()

    # -- the host-level vocabulary --------------------------------------------
    @property
    def n_hosts(self) -> int:
        return self.n_shards

    def resolve_hosts(self, default_ssd: SSDSpec) -> tuple[HostLinkSpec, ...]:
        """Per-host `HostLinkSpec`s with every `ssd=None` filled from the
        loader's device — what the loader wires into
        `StorageTimeline.host_specs`."""
        return tuple(h if h.ssd is not None else h.with_ssd(default_ssd)
                     for h in self.hosts)

    def resolve_shard_specs(self, default_spec) -> tuple:
        """Each host's local SSD is its shard's device."""
        return tuple(h.ssd if h.ssd is not None else default_spec
                     for h in self.hosts)

    def topo_host_of(self, node_ids: np.ndarray) -> np.ndarray:
        """Host owning each node's adjacency (== `shard_of` under
        co-partitioning — the agreement property)."""
        return self._topo_host[np.asarray(node_ids, np.int64)]

    def requester_of(self, node_ids: np.ndarray) -> np.ndarray:
        return self._requester[np.asarray(node_ids, np.int64)]

    def remote_mask(self, node_ids: np.ndarray,
                    serving_shard: np.ndarray) -> np.ndarray:
        """True where the serving host differs from the requester —
        `build_plan` stamps this into `GatherPlan.remote` and the priced
        burst ships those rows' lines over the serving hosts' links."""
        req = self._requester[np.asarray(node_ids, np.int64)]
        return req != np.asarray(serving_shard, np.int16)

    def topology_page_shard(self, page_bytes: int = IO_BYTES) -> np.ndarray:
        """Per-page host assignment for the topology store — each CSR edge
        page goes to the host owning its first edge word's node, resolved
        against THIS tier's topology-host table (co-partitioned or
        independent), so the loader builds one cluster, not two."""
        if self.graph is None:
            raise ValueError(
                f"{self.name}: topology_page_shard needs the graph — build "
                "the tier with graph= (the host_storage factory passes it)")
        indices = self.graph.indices
        indptr = np.asarray(self.graph.indptr, np.int64)
        page_words = max(1, int(page_bytes) // indices.dtype.itemsize)
        n_pages = max(1, -(-len(indices) // page_words))
        first = np.minimum(np.arange(n_pages, dtype=np.int64) * page_words,
                           max(len(indices) - 1, 0))
        owner = np.searchsorted(indptr, first, side="right") - 1
        owner = np.clip(owner, 0, len(indptr) - 2)
        return self._topo_host[owner].astype(np.int16)

    # -- telemetry -------------------------------------------------------------
    def cut_edge_fraction(self) -> float:
        """Fraction of edges crossing hosts under this tier's topology
        placement (0.0 without a graph)."""
        if self.graph is None:
            return 0.0
        return cut_edge_fraction(self.graph.indptr, self.graph.indices,
                                 self._topo_host)

    def remote_fraction(self) -> float:
        """Expected fraction of the namespace whose requester differs from
        its PRIMARY feature shard — the static cross-host traffic share
        (failover rerouting can shift the realized value)."""
        n = len(self.features)
        primary = np.asarray(self.placement.shard_of(np.arange(n)), np.int16)
        return float(np.mean(self._requester != primary))

    def record_metrics(self, registry) -> None:
        """Fold the cluster's static placement telemetry into a
        MetricsRegistry (repro.obs): host count, the placement's expected
        cross-host request share, and the edge-cut fraction the metis-lite
        partitioner minimizes.  Per-burst realized traffic lands in the
        registry separately via `StorageTimeline._note_burst`."""
        registry.gauge("hosts.n_hosts").set(self.n_hosts)
        registry.gauge("hosts.placement_remote_fraction").set(
            self.remote_fraction())
        registry.gauge("hosts.cut_edge_fraction").set(
            self.cut_edge_fraction())

    # -- checkpoint ------------------------------------------------------------
    def state_dict(self) -> dict:
        return {**super().state_dict(), "co_partition": self.co_partition}

    def load_state_dict(self, state: dict) -> None:
        if bool(state.get("co_partition", self.co_partition)) \
                != self.co_partition:
            raise ValueError(
                f"checkpoint is {'co-partitioned' if state.get('co_partition') else 'independent'}, "
                f"tier is {'co-partitioned' if self.co_partition else 'independent'} "
                "— the topology-host table would not round-trip")
        super().load_state_dict(state)
        # the placement table may have been restored (adaptive bases):
        # rebuild the derived host tables so topology/requester stay in sync
        n = len(self.features)
        if self.co_partition:
            self._topo_host = np.asarray(
                self.placement.topology_host_of(np.arange(n)), np.int16)
            if self.graph is not None:
                self._requester = requester_hosts(
                    self.graph.indptr, self.graph.indices, self._topo_host,
                    self.n_shards)
            else:
                self._requester = self._topo_host.copy()
