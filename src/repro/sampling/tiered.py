"""Tiered GPU-initiated sampling — the priced twin of `host_sample_blocks`.

`tiered_sample_blocks` runs the exact host sampling math (the shared
`neighbor.sample_hop`, consuming the SAME `np.random.Generator` stream, so
blocks are bit-identical to `host_sample_blocks` given the same RNG
snapshot) while additionally resolving every adjacency read against a
`TieredTopologyStore` (core/topology.py): per hop it records which 4 KB
edge pages the sampled reads touched, splits them by placement tier
(GPU-resident hot adjacency / pinned host / storage-backed CSR pages),
and prices the hop through the store's `StorageTimeline` — producing one
`TopologyGatherReport` per hop and a total modelled `sample_time_s`.

That report is what turns `GIDSDataLoader.plan_next()` into a *priced*
pipeline stage: a topology plane (`gids-topo`, `gids-topo-merged`) folds
`sample_time_s` into `Batch.prep_time_s`, so `exposed_prep_s` finally
covers sampling AND feature gather (the paper's full Fig. 1 prep path),
not just the gather half.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.topology import TieredTopologyStore, TopologyGatherReport
from repro.graph.csr import CSRGraph
from .neighbor import SampledBlocks, run_sample_hops


@dataclasses.dataclass
class TieredSampledBlocks(SampledBlocks):
    """`SampledBlocks` plus the topology plane's sampling telemetry:
    one priced `TopologyGatherReport` per hop and their summed modelled
    time.  Block fields are bit-identical to the host sampler's."""

    hop_reports: list = dataclasses.field(default_factory=list)
    sample_time_s: float = 0.0


def tiered_sample_blocks(graph: CSRGraph, topo: TieredTopologyStore,
                         seeds: np.ndarray, fanouts: Sequence[int],
                         rng: np.random.Generator,
                         tracer=None) -> TieredSampledBlocks:
    """`tracer` (repro.obs) wall-clocks the whole sampling sweep and
    attaches the summed priced hop time — observation only, the sampled
    blocks and the per-hop reports are identical with or without it."""
    if tracer is None:
        from repro.obs import NULL_TRACER as tracer  # noqa: N811
    reports: list[TopologyGatherReport] = []

    def price_hop(hop: int, read_pos: np.ndarray, n_frontier: int) -> None:
        # only destinations with edges physically read adjacency words; a
        # degree-0 row's positions are self-loop padding (the driver
        # already filtered them out of read_pos)
        reports.append(topo.hop_report(read_pos, hop=hop,
                                       n_frontier=n_frontier))

    with tracer.stage("sample", cat="sample", seeds=len(seeds)) as sp:
        hop_nodes, all_nodes, n_req = run_sample_hops(graph, seeds, fanouts,
                                                      rng, hop_cb=price_hop)
        sample_time_s = float(sum(r.time_s for r in reports))
        sp.modelled(sample_time_s)
    return TieredSampledBlocks(
        seeds=seeds, hop_nodes=hop_nodes, all_nodes=all_nodes,
        num_requests=n_req, hop_reports=reports,
        sample_time_s=sample_time_s)
