"""Device-resident GIDS tier: jittable cache + Pallas gather end-to-end."""
import jax.numpy as jnp
import numpy as np

from repro.core import device_store as ds


def test_device_gather_roundtrip_and_hits():
    rng = np.random.default_rng(0)
    N, D = 500, 64
    feats = rng.standard_normal((N, D)).astype(np.float32)

    store = ds.init_store(num_lines=128, dim=D, ways=4)
    ids1 = np.unique(rng.integers(0, N, 32)).astype(np.int32)
    B = len(ids1)
    staged1 = jnp.asarray(feats[ids1])
    fc = jnp.zeros(B, jnp.int32)
    store, rows1, hits1 = ds.device_gather(store, jnp.asarray(ids1),
                                           staged1, fc)
    np.testing.assert_allclose(rows1, feats[ids1])   # correct rows
    assert not bool(hits1.any())                     # cold cache

    # second access: same ids -> hits served from the device row store,
    # even with garbage staged rows (proves rows come from the cache)
    garbage = jnp.zeros((B, D), jnp.float32)
    store, rows2, hits2 = ds.device_gather(store, jnp.asarray(ids1),
                                           garbage, fc)
    assert bool(hits2.all())
    np.testing.assert_allclose(rows2, feats[ids1])


def test_device_gather_window_pinning():
    rng = np.random.default_rng(1)
    N, D = 200, 32
    feats = rng.standard_normal((N, D)).astype(np.float32)
    store = ds.init_store(num_lines=16, dim=D, ways=4)

    hot = np.array([7], dtype=np.int32)
    # access hot once (fills), then push a window announcing reuse
    store, _, _ = ds.device_gather(store, jnp.asarray(hot),
                                   jnp.asarray(feats[hot]),
                                   jnp.zeros(1, jnp.int32))
    store = store._replace(
        cache=ds.push_window(store.cache, jnp.asarray(hot)))
    # storm of conflicting ids cannot evict the pinned line
    for i in range(6):
        ids = (hot + 16 * (i + 1)).astype(np.int32)  # same set, diff tags
        store, _, _ = ds.device_gather(store, jnp.asarray(ids),
                                       jnp.asarray(feats[ids]),
                                       jnp.zeros(1, jnp.int32))
    store, rows, hits = ds.device_gather(store, jnp.asarray(hot),
                                         jnp.zeros((1, D), jnp.float32),
                                         jnp.zeros(1, jnp.int32))
    assert bool(hits[0]), "pinned hot line was evicted"
    np.testing.assert_allclose(rows, feats[hot])
