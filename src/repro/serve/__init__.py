from repro.core.tiers import KVSlotTier, TenantCacheTier
from .admission import SLOBatcher, WindowDecision
from .engine import EngineConfig, EngineNotDrained, Request, ServeEngine
from .gnn_engine import (BrownoutController, GNNServeConfig, GNNServeEngine,
                         RequestRecord, ServeResult, WindowTrace)
from .workload import (ServeRequest, TenantSpec, generate_stream,
                       mmpp_arrivals, poisson_arrivals, tenant_hot_set)

__all__ = [
    "BrownoutController",
    "EngineConfig", "EngineNotDrained", "GNNServeConfig", "GNNServeEngine",
    "KVSlotTier", "Request", "RequestRecord", "SLOBatcher", "ServeEngine",
    "ServeRequest", "ServeResult", "TenantCacheTier", "TenantSpec",
    "WindowDecision", "WindowTrace", "generate_stream", "mmpp_arrivals",
    "poisson_arrivals", "tenant_hot_set",
]
