"""Declarative data-plane composition: `DataPlaneSpec` and the preset
registry that replaces the old `mode="gids"|"bam"|"mmap"` strings.

A spec is data, not code: an ordered tuple of `TierSpec`s (kind + params)
plus the orchestration policies the loader needs — how storage time is
priced (`pricing`), whether sampling runs ahead under the accumulator
(`lookahead`), and how many batches the prefetch engine stages ahead of
consumption (`prefetch`; see core/prefetch.py — the `gids-async` preset).  `build()` resolves each TierSpec through the tier-kind
factory registry against a `BuildContext` (graph, features, and the sizing
knobs LoaderConfig carries) and returns a `DataPlane` wrapping a
`TieredFeatureStore`.

    plane = DataPlaneSpec.preset("gids").build(graph, features)
    rows, report = plane.store.gather(node_ids)

The paper's three baselines are presets; new stacks register alongside them:

    DataPlaneSpec.register(DataPlaneSpec(
        name="my-plane",
        tiers=(tier("constant_buffer", fraction=0.5), tier("storage"))))

Tier kinds themselves are also open — `register_tier_kind` admits user
factories; the `sharded_storage` kind (a `ShardedStorageTier` over a
registered placement policy, see core/sharding.py) and the prefetching
presets both arrived through this seam.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

from .constant_buffer import ConstantBuffer
from .feature_store import TieredFeatureStore
from .software_cache import WindowBufferedCache
from .storage_sim import StorageTimeline
from .tiers import (ConstantBufferTier, DeviceCacheTier, KVSlotTier,
                    StorageTier, Tier)


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One tier in a declarative stack: a registered kind plus overrides.
    Params left unset fall back to the BuildContext knobs, so one spec
    serves every graph/feature size."""

    kind: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)


def tier(kind: str, **params) -> TierSpec:
    """Sugar: `tier("window_cache", window_depth=0)`."""
    return TierSpec(kind, params)


@dataclasses.dataclass
class BuildContext:
    """Everything a tier factory may need.  Field names deliberately mirror
    `LoaderConfig` so `build(config=cfg)` maps knobs across by name."""

    graph: Any = None
    features: Any = None
    cache_lines: int = 1 << 15
    cache_ways: int = 8
    window_depth: int = 8
    cbuf_fraction: float = 0.1
    cbuf_selection: str = "pagerank"
    seed: int = 0
    # sharded-storage knobs (multi-SSD namespace)
    n_shards: int = 1
    placement: str = "hash"
    shard_specs: Any = None           # per-shard SSDSpecs (heterogeneous)
    # fault-plane knob: k-way replication (ReplicatedPlacement wrapped
    # around the placement policy) so failover/hedged reads have somewhere
    # to go; 1 = unreplicated, bit-identical to the bare policy
    replication_factor: int = 1
    # multi-host plane knobs (core/hosts.py): shards become hosts with an
    # interconnect each; co_partition drives features AND edge pages off
    # one placement decision; host_link overrides the default 100GbE spec
    n_hosts: int = 1
    co_partition: bool = True
    host_link: Any = None             # HostLinkSpec | per-host sequence
    # serve-engine knobs (KV slot pool)
    slots: int = 0
    bytes_per_slot: int = 0
    # multi-tenant serving knobs (per-tenant cache partitions)
    tenants: int = 1
    tenant_quotas: Any = None         # per-tenant capacity shares, None=equal

    _KNOBS = ("cache_lines", "cache_ways", "window_depth", "cbuf_fraction",
              "cbuf_selection", "seed", "n_shards", "placement",
              "replication_factor", "n_hosts", "co_partition", "host_link",
              "tenants", "tenant_quotas")

    def absorb(self, config: Any) -> "BuildContext":
        for k in self._KNOBS:
            if config is not None and hasattr(config, k):
                setattr(self, k, getattr(config, k))
        return self


# -- tier-kind factory registry -----------------------------------------------

TierFactory = Callable[..., "Tier | None"]
_TIER_KINDS: dict[str, TierFactory] = {}


def register_tier_kind(kind: str) -> Callable[[TierFactory], TierFactory]:
    """Register a factory `(ctx: BuildContext, **params) -> Tier | None`.
    Returning None omits the tier (e.g. a constant buffer at fraction 0)."""
    def deco(fn: TierFactory) -> TierFactory:
        _TIER_KINDS[kind] = fn
        return fn
    return deco


@register_tier_kind("window_cache")
def _make_window_cache(ctx: BuildContext, num_lines=None, ways=None,
                       window_depth=None, evict="random") -> Tier:
    num_lines = ctx.cache_lines if num_lines is None else num_lines
    ways = ctx.cache_ways if ways is None else ways
    window_depth = ctx.window_depth if window_depth is None else window_depth
    return DeviceCacheTier(WindowBufferedCache(
        num_lines, ways, window_depth=window_depth, seed=ctx.seed,
        evict=evict))


@register_tier_kind("constant_buffer")
def _make_constant_buffer(ctx: BuildContext, fraction=None,
                          selection=None) -> Tier | None:
    fraction = ctx.cbuf_fraction if fraction is None else fraction
    selection = ctx.cbuf_selection if selection is None else selection
    if fraction <= 0:
        return None                           # legitimate omit (Fig. 10/11)
    if ctx.graph is None:
        raise ValueError(
            "constant_buffer tier needs a graph in the BuildContext to rank "
            "hot nodes; pass build(graph, ...) or set fraction=0 to omit it")
    cbuf = ConstantBuffer.from_graph(ctx.graph, fraction,
                                     selection=selection, seed=ctx.seed)
    row_bytes = None
    if ctx.features is not None:
        row_bytes = ctx.features.shape[1] * ctx.features.dtype.itemsize
    return ConstantBufferTier(cbuf, row_bytes=row_bytes)


@register_tier_kind("device_store")
def _make_device_store(ctx: BuildContext, num_lines=None, ways=None,
                       window_depth=None, use_pallas=False) -> Tier:
    from .tiers import DeviceStoreTier            # deferred: pulls in jax
    num_lines = ctx.cache_lines if num_lines is None else num_lines
    ways = ctx.cache_ways if ways is None else ways
    window_depth = ctx.window_depth if window_depth is None else window_depth
    return DeviceStoreTier(ctx.features, num_lines, ways=ways,
                           window_depth=window_depth, use_pallas=use_pallas)


@register_tier_kind("storage")
def _make_storage(ctx: BuildContext) -> Tier:
    if ctx.features is None:
        raise ValueError("storage tier needs features in the BuildContext")
    return StorageTier(ctx.features)


@register_tier_kind("sharded_storage")
def _make_sharded_storage(ctx: BuildContext, n_shards=None, placement=None,
                          specs=None) -> Tier:
    """The storage backstop partitioned across `n_shards` SSD queues by a
    registered placement policy (core/sharding.py: hash / range / degree /
    skewed, plus user registrations).  `specs` may be a single SSDSpec or
    one per shard (heterogeneous arrays)."""
    from .sharding import ReplicatedPlacement, make_placement
    from .tiers import ShardedStorageTier
    if ctx.features is None:
        raise ValueError("sharded_storage tier needs features in the "
                         "BuildContext")
    n_shards = ctx.n_shards if n_shards is None else n_shards
    placement = ctx.placement if placement is None else placement
    degrees = None
    if ctx.graph is not None and hasattr(ctx.graph, "degrees"):
        degrees = ctx.graph.degrees()
    policy = make_placement(placement, n_shards,
                            num_nodes=len(ctx.features), degrees=degrees,
                            graph=ctx.graph, seed=ctx.seed)
    if ctx.replication_factor > 1:
        # k-way replication for the fault plane; validates loudly (k vs
        # n_shards) at build time rather than at first failover
        policy = ReplicatedPlacement(policy, ctx.replication_factor)
    specs = ctx.shard_specs if specs is None else specs
    return ShardedStorageTier(ctx.features, policy, specs=specs)


@register_tier_kind("host_storage")
def _make_host_storage(ctx: BuildContext, n_hosts=None, placement=None,
                       co_partition=None, hosts=None) -> Tier:
    """The storage backstop partitioned across a CLUSTER (core/hosts.py):
    each shard is a host — interconnect + local SSD — and the placement
    decision, co-partitioned by default, drives both the feature rows and
    the CSR edge pages of every node.  Replication (if any) spreads copies
    across hosts as failure domains."""
    import numpy as np

    from .hosts import HostShardTier
    from .sharding import ReplicatedPlacement, make_placement
    if ctx.features is None:
        raise ValueError("host_storage tier needs features in the "
                         "BuildContext")
    n_hosts = ctx.n_hosts if n_hosts is None else n_hosts
    placement = ctx.placement if placement is None else placement
    co = ctx.co_partition if co_partition is None else co_partition
    hosts = ctx.host_link if hosts is None else hosts
    degrees = None
    if ctx.graph is not None and hasattr(ctx.graph, "degrees"):
        degrees = ctx.graph.degrees()
    policy = make_placement(placement, n_hosts,
                            num_nodes=len(ctx.features), degrees=degrees,
                            graph=ctx.graph, seed=ctx.seed)
    if ctx.replication_factor > 1:
        # hosts are failure domains: replica j of a row must land on a
        # DIFFERENT host, so a whole-host outage cannot lose data
        policy = ReplicatedPlacement(policy, ctx.replication_factor,
                                     failure_domains=np.arange(n_hosts))
    return HostShardTier(ctx.features, policy, hosts=hosts,
                         graph=ctx.graph, co_partition=co, seed=ctx.seed)


@register_tier_kind("tenant_cache")
def _make_tenant_cache(ctx: BuildContext, num_lines=None, ways=None,
                       tenants=None, quotas=None) -> Tier:
    """Per-tenant partitioned HBM software cache for the serve plane
    (`TenantCacheTier`): the line budget is split by tenant quota and a
    tenant only fills/evicts inside its own partition, so a noisy tenant
    cannot evict another tenant's hot set."""
    from .tiers import TenantCacheTier
    num_lines = ctx.cache_lines if num_lines is None else num_lines
    ways = ctx.cache_ways if ways is None else ways
    tenants = ctx.tenants if tenants is None else tenants
    quotas = ctx.tenant_quotas if quotas is None else quotas
    return TenantCacheTier(num_lines, ways, tenants=tenants, quotas=quotas,
                           seed=ctx.seed)


@register_tier_kind("kv_slots")
def _make_kv_slots(ctx: BuildContext, slots=None, bytes_per_slot=None) -> Tier:
    slots = ctx.slots if slots is None else slots
    bytes_per_slot = (ctx.bytes_per_slot if bytes_per_slot is None
                      else bytes_per_slot)
    return KVSlotTier(slots, bytes_per_slot)


# -- the spec ------------------------------------------------------------------

_PRESETS: dict[str, "DataPlaneSpec"] = {}


@dataclasses.dataclass(frozen=True)
class DataPlaneSpec:
    """Declarative description of a data plane.

    pricing:   "overlapped"  — storage requests overlap under the
                                accumulator's outstanding count (GIDS/BaM)
               "page_fault"  — serial fault handling (the mmap baseline)
    lookahead: sampling runs ahead of training under accumulator control;
               False degenerates to synchronous depth-1 sampling.
    prefetch:  batches the `PrefetchEngine` (core/prefetch.py) stages ahead
               of consumption; 0 = synchronous execute-on-demand.  A
               prefetching plane prices *exposed* prep time — the portion of
               the modelled prep that the previous batch's model compute
               did not hide (`StorageTimeline.price_batch_overlapped`).
    merge_execute: execute whole merged windows instead of single batches
               (`GIDSDataLoader.execute_window`): the accumulator's staged
               lookahead is deduplicated across batches (`MergedWindow`),
               the tier stack folds once over the unique set, storage-bound
               rows sharing a 4 KB line coalesce into single IOs, and the
               window is priced as one burst
               (`StorageTimeline.price_merged_burst`, amortized per batch).
               Per-batch features stay bit-identical to the per-batch path;
               only modelled time and tier telemetry change.  Requires
               "overlapped" pricing (a page-fault plane has no burst to
               merge).
    topology:  sampling runs against a `TieredTopologyStore`
               (core/topology.py): the CSR adjacency is partitioned into
               page-granular tiers (GPU hot adjacency / pinned host /
               storage-backed CSR pages), every hop's edge-page reads are
               priced, and `Batch.prep_time_s` (hence `exposed_prep_s`)
               includes the modelled sampling time — `plan_next()` becomes
               a priced stage symmetrical to `execute()`.  Blocks and
               features stay bit-identical to the un-tiered plane.
    """

    name: str
    tiers: tuple[TierSpec, ...]
    pricing: str = "overlapped"
    lookahead: bool = True
    prefetch: int = 0
    merge_execute: bool = False
    topology: bool = False
    description: str = ""

    def __post_init__(self) -> None:
        if self.merge_execute and self.pricing != "overlapped":
            raise ValueError(
                f"spec {self.name!r}: merge_execute requires 'overlapped' "
                f"pricing (got {self.pricing!r}) — a serially-faulting "
                "plane has no merged burst to price")

    def with_(self, **overrides) -> "DataPlaneSpec":
        return dataclasses.replace(self, **overrides)

    # -- construction ---------------------------------------------------------
    def build_stack(self, ctx: BuildContext | None = None,
                    **ctx_kwargs) -> list[Tier]:
        """Resolve the TierSpecs into live tiers (None results omitted)."""
        ctx = ctx or BuildContext(**ctx_kwargs)
        out = []
        for ts in self.tiers:
            try:
                factory = _TIER_KINDS[ts.kind]
            except KeyError:
                raise KeyError(
                    f"unknown tier kind {ts.kind!r}; registered: "
                    f"{sorted(_TIER_KINDS)}") from None
            t = factory(ctx, **dict(ts.params))
            if t is not None:
                out.append(t)
        return out

    def build(self, graph=None, features=None, config=None,
              **overrides) -> "DataPlane":
        """One factory for every consumer (loader, benchmarks, examples):
        `DataPlaneSpec.preset("gids").build(graph, features)`."""
        ctx = BuildContext(graph=graph, features=features).absorb(config)
        valid = {f.name for f in dataclasses.fields(BuildContext)}
        for k, v in overrides.items():
            if k not in valid:
                raise TypeError(f"unknown build override {k!r}; "
                                f"valid knobs: {sorted(valid)}")
            setattr(ctx, k, v)
        return DataPlane(spec=self,
                         store=TieredFeatureStore(self.build_stack(ctx)))

    # -- registry -------------------------------------------------------------
    @staticmethod
    def preset(name: str, **overrides) -> "DataPlaneSpec":
        try:
            spec = _PRESETS[name]
        except KeyError:
            raise KeyError(f"unknown data-plane preset {name!r}; "
                           f"available: {DataPlaneSpec.names()}") from None
        return spec.with_(**overrides) if overrides else spec

    @staticmethod
    def register(spec: "DataPlaneSpec",
                 overwrite: bool = False) -> "DataPlaneSpec":
        if spec.name in _PRESETS and not overwrite:
            raise ValueError(f"preset {spec.name!r} already registered")
        _PRESETS[spec.name] = spec
        return spec

    @staticmethod
    def names() -> tuple[str, ...]:
        return tuple(sorted(_PRESETS))

    @staticmethod
    def resolve(obj: "DataPlaneSpec | str") -> "DataPlaneSpec":
        if isinstance(obj, DataPlaneSpec):
            return obj
        if isinstance(obj, str):
            return DataPlaneSpec.preset(obj)
        raise TypeError(f"expected DataPlaneSpec or preset name, got {obj!r}")


@dataclasses.dataclass
class DataPlane:
    """A built data plane: the tier stack plus the orchestration policies the
    loader reads instead of branching on mode strings."""

    spec: DataPlaneSpec
    store: TieredFeatureStore

    @property
    def pricing(self) -> str:
        return self.spec.pricing

    @property
    def lookahead(self) -> bool:
        return self.spec.lookahead

    @property
    def min_lookahead(self) -> int:
        """Lookahead floor: a windowed tier needs its window kept full."""
        wt = self.store.windowed_tier
        return max(1, wt.window_depth if wt is not None else 1)

    @property
    def prefetch_depth(self) -> int:
        return self.spec.prefetch

    @property
    def merge_execute(self) -> bool:
        return self.spec.merge_execute

    @property
    def topology(self) -> bool:
        return self.spec.topology

    def price(self, timeline: StorageTimeline, report,
              outstanding: int) -> float:
        return timeline.price_batch(report, outstanding=outstanding,
                                    policy=self.spec.pricing)

    def exposed_prep(self, timeline: StorageTimeline, prep_s: float,
                     compute_s: float) -> float:
        """Critical-path prep time the consumer actually waits for.  Only a
        prefetching plane overlaps prep with the previous batch's compute; a
        synchronous plane exposes the full modelled prep."""
        if self.prefetch_depth > 0:
            return timeline.price_batch_overlapped(prep_s, compute_s)
        return prep_s

    def reset(self) -> None:
        self.store.reset()


# -- the paper's baselines + composable extras, as presets ---------------------

DataPlaneSpec.register(DataPlaneSpec(
    name="gids",
    tiers=(tier("window_cache"), tier("constant_buffer"), tier("storage")),
    pricing="overlapped", lookahead=True,
    description="Paper §3: window-buffered HBM cache + constant pinned-host "
                "buffer + GPU-initiated direct storage, accumulator-merged."))

DataPlaneSpec.register(DataPlaneSpec(
    name="bam",
    tiers=(tier("window_cache", window_depth=0), tier("storage")),
    pricing="overlapped", lookahead=True,
    description="BaM baseline: random-eviction GPU cache over direct "
                "storage; no window buffering, no host buffer."))

DataPlaneSpec.register(DataPlaneSpec(
    name="mmap",
    tiers=(tier("storage"),),
    pricing="page_fault", lookahead=False,
    description="DGL-mmap baseline: synchronous sampling, page-fault-priced "
                "storage, no redirection tiers."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-async",
    tiers=(tier("window_cache"), tier("constant_buffer"), tier("storage")),
    pricing="overlapped", lookahead=True, prefetch=2,
    description="GIDS with the two-stage prefetch engine: batch k+1's "
                "gather/staging executes while batch k trains, so only "
                "prep time in excess of the compute time is exposed "
                "(§3.2 decoupling, Fig. 13 overlap)."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-merged",
    tiers=(tier("window_cache"), tier("constant_buffer"), tier("storage")),
    pricing="overlapped", lookahead=True, merge_execute=True,
    description="GIDS with the accumulator's merge EXECUTED, not just "
                "sized: the staged lookahead window is deduplicated across "
                "batches, each unique row gathered once, 4 KB-line-sharing "
                "storage rows coalesced into single IOs, and the window "
                "priced as one burst amortized per batch (§3.2)."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-merged-async",
    tiers=(tier("window_cache"), tier("constant_buffer"), tier("storage")),
    pricing="overlapped", lookahead=True, prefetch=2, merge_execute=True,
    description="Merged-window execution combined with the prefetch "
                "engine: whole deduplicated windows are staged ahead of "
                "consumption and each batch's amortized burst share is "
                "discounted by the compute it overlapped."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-sharded",
    tiers=(tier("window_cache"), tier("constant_buffer"),
           tier("sharded_storage")),
    pricing="overlapped", lookahead=True,
    description="GIDS over a storage namespace partitioned across n_shards "
                "SSD queues (BuildContext.n_shards / LoaderConfig.n_shards; "
                "placement policy from core/sharding.py): each shard drains "
                "its own queue at its own spec and the batch completes at "
                "the slowest shard (§4.2 multi-SSD scaling, per-queue)."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-merged-sharded",
    tiers=(tier("window_cache"), tier("constant_buffer"),
           tier("sharded_storage")),
    pricing="overlapped", lookahead=True, merge_execute=True,
    description="Merged-window execution over the sharded namespace: the "
                "deduplicated window's storage rows split per shard, 4 KB-"
                "line coalescing is shard-local ((shard, line) keys), and "
                "the window prices as per-shard bursts completing at the "
                "max over shards (straggler telemetry included)."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-hosts",
    tiers=(tier("window_cache"), tier("constant_buffer"),
           tier("host_storage")),
    pricing="overlapped", lookahead=True,
    description="GIDS over a multi-host cluster (BuildContext.n_hosts; "
                "core/hosts.py): each shard is a host with its own link + "
                "local SSD, one co-partitioned placement decision drives "
                "features and CSR edge pages, and rows requested across "
                "hosts pay the serving host's link transit on top of its "
                "local drain (max-over-hosts completion)."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-hosts-merged",
    tiers=(tier("window_cache"), tier("constant_buffer"),
           tier("host_storage")),
    pricing="overlapped", lookahead=True, merge_execute=True,
    description="Merged-window execution over the host cluster: two-level "
                "coalescing — the window dedups per host ((shard, line) "
                "keys), then each host's remote lines ship 4 KB-granular "
                "over its link — priced as per-host bursts completing at "
                "the max over hosts.  n_hosts=1 is bit-identical to "
                "gids-merged."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-topo",
    tiers=(tier("window_cache"), tier("constant_buffer"), tier("storage")),
    pricing="overlapped", lookahead=True, topology=True,
    description="GIDS with the topology plane: sampling reads a tiered "
                "adjacency store (GPU hot pages + pinned host + storage-"
                "backed CSR pages, degree-aware admission) and is PRICED — "
                "exposed prep covers sampling and gather, per-hop tier "
                "splits reported (Fig. 7 sampling-throughput story)."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-topo-merged",
    tiers=(tier("window_cache"), tier("constant_buffer"), tier("storage")),
    pricing="overlapped", lookahead=True, merge_execute=True, topology=True,
    description="Topology-tiered sampling composed with merged-window "
                "execution: each batch's priced sampling time rides on top "
                "of its amortized share of the window's coalesced feature "
                "burst."))

DataPlaneSpec.register(DataPlaneSpec(
    name="pinned-host",
    tiers=(tier("constant_buffer"), tier("storage")),
    pricing="overlapped", lookahead=True,
    description="PyTorch-Direct-style zero-copy plane: pinned-host hot set "
                "over direct storage, no device cache."))

DataPlaneSpec.register(DataPlaneSpec(
    name="gids-device",
    tiers=(tier("device_store"), tier("constant_buffer"), tier("storage")),
    pricing="overlapped", lookahead=True,
    description="GIDS with the fully-jittable HBM tier (cache_jax metadata "
                "+ Pallas tiered_gather) in place of the numpy reference."))

DataPlaneSpec.register(DataPlaneSpec(
    name="serve-gnn",
    tiers=(tier("tenant_cache"), tier("constant_buffer", fraction=0.05),
           tier("storage")),
    pricing="overlapped", lookahead=False,
    description="Online GNN inference plane: per-tenant partitioned HBM "
                "cache (quota-bounded eviction — a noisy tenant cannot "
                "evict another tenant's hot set) over a small pinned-host "
                "hot set and direct storage.  No epoch lookahead; request "
                "windows are deadline-bounded by the serve engine."))

DataPlaneSpec.register(DataPlaneSpec(
    name="serve-gnn-shared",
    tiers=(tier("window_cache", window_depth=0),
           tier("constant_buffer", fraction=0.05), tier("storage")),
    pricing="overlapped", lookahead=False,
    description="The serve plane WITHOUT tenant isolation: one shared "
                "random-eviction cache all tenants contend for — the "
                "noisy-neighbour baseline fig_serve_load compares against."))

DataPlaneSpec.register(DataPlaneSpec(
    name="serve-kv",
    tiers=(tier("kv_slots"),),
    pricing="overlapped", lookahead=False,
    description="Serve engine's KV-cache slot pool as a single-tier plane "
                "(no storage backstop — requests queue when it is full)."))
