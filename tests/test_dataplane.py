"""Pluggable tiered data-plane API: tier-stack composition order, preset
registry round-trip vs the deprecated mode= shim, the partition property of
per-request tier assignment, kernel-slot wiring, and KV-slot recycling."""
import numpy as np
import pytest

from repro.core import (DataPlaneSpec, GIDSDataLoader, KVSlotTier,
                        LoaderConfig, TierSpec, TieredFeatureStore, tier)
from repro.core.constant_buffer import ConstantBuffer
from repro.core.software_cache import WindowBufferedCache
from repro.core.tiers import (ConstantBufferTier, DeviceCacheTier,
                              StorageTier, build_plan)
from repro.graph.synthetic import rmat_graph


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(8_000, 10, 16, seed=3)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    return g, feats


def _stack(feats, num_nodes, cache_lines=0, cbuf_ids=None, seed=0,
           window_depth=0):
    tiers = []
    if cache_lines:
        tiers.append(DeviceCacheTier(WindowBufferedCache(
            cache_lines, ways=4, window_depth=window_depth, seed=seed)))
    if cbuf_ids is not None:
        tiers.append(ConstantBufferTier(
            ConstantBuffer(num_nodes, cbuf_ids)))
    tiers.append(StorageTier(feats))
    return tiers


# -- partition property --------------------------------------------------------

def test_plan_assignment_is_partition_property():
    """Every request is served by exactly one tier, across random stacks,
    random batches, and repeated (stateful) probing."""
    rng = np.random.default_rng(7)
    N, D = 2000, 8
    feats = rng.standard_normal((N, D)).astype(np.float32)
    for trial in range(20):
        cache_lines = int(rng.choice([0, 64, 256, 1024]))
        with_cbuf = bool(rng.integers(0, 2))
        cbuf_ids = (np.unique(rng.integers(0, N, rng.integers(1, N // 2)))
                    if with_cbuf else None)
        tiers = _stack(feats, N, cache_lines=cache_lines, cbuf_ids=cbuf_ids,
                       seed=trial)
        for _ in range(4):                     # cache state evolves
            ids = np.unique(rng.integers(0, N, rng.integers(1, 400)))
            plan = build_plan(tiers, ids)
            assert plan.is_partition()
            # exactly-one-tier, stated directly: the per-tier masks are
            # disjoint and cover the batch
            masks = [plan.mask(i) for i in range(len(tiers))]
            assert (np.sum(masks, axis=0) == 1).all()
            assert int(plan.counts().sum()) == len(ids)


def test_stack_without_backstop_fails_loudly():
    N = 100
    cbuf = ConstantBufferTier(ConstantBuffer(N, np.arange(10)))
    with pytest.raises(RuntimeError, match="backstop"):
        build_plan([cbuf], np.arange(50))
    feats = np.zeros((N, 4), np.float32)
    with pytest.raises(ValueError, match="backstop"):
        TieredFeatureStore([cbuf])
    del feats


# -- composition order ---------------------------------------------------------

def test_composition_order_changes_tier_split():
    """The fold offers each tier only what faster tiers declined, so stack
    order is semantic: once the cache is warm, cache-first claims requests
    the cbuf would otherwise serve."""
    rng = np.random.default_rng(0)
    N, D = 1000, 8
    feats = rng.standard_normal((N, D)).astype(np.float32)
    pinned = np.arange(0, N, 2)                # half the nodes
    ids = np.unique(rng.integers(0, N, 300))

    def run(order):
        cache = DeviceCacheTier(WindowBufferedCache(1 << 12, ways=4, seed=0))
        cbuf = ConstantBufferTier(ConstantBuffer(N, pinned))
        stack = ([cache, cbuf] if order == "cache_first" else [cbuf, cache])
        stack.append(StorageTier(feats))
        store = TieredFeatureStore(stack)
        store.gather(ids)                      # warm the cache
        _, report = store.gather(ids)
        return report

    cache_first = run("cache_first")
    cbuf_first = run("cbuf_first")
    # warm cache claims everything when probed first...
    assert cache_first.n_hbm_hits == len(ids)
    assert cache_first.n_host_hits == 0
    # ...but the cbuf intercepts its pinned nodes when it comes first
    assert cbuf_first.n_host_hits == int(np.sum(ids % 2 == 0))
    assert cbuf_first.n_hbm_hits == len(ids) - cbuf_first.n_host_hits


# -- preset registry round-trip vs mode= shim ----------------------------------

@pytest.mark.parametrize("mode", ["gids", "bam", "mmap"])
def test_preset_equivalent_to_deprecated_mode_shim(graph_and_feats, mode):
    g, feats = graph_and_feats
    kw = dict(batch_size=128, fanouts=(4, 3), cache_lines=2048,
              window_depth=4, seed=5)
    with pytest.warns(DeprecationWarning):
        old = GIDSDataLoader(g, feats, LoaderConfig(mode=mode, **kw))
    new = GIDSDataLoader(g, feats, LoaderConfig(data_plane=mode, **kw))
    for _ in range(6):
        bo, bn = old.next_batch(), new.next_batch()
        assert bo.report == bn.report
        assert bo.prep_time_s == bn.prep_time_s
        assert bo.merge_depth == bn.merge_depth
        np.testing.assert_array_equal(bo.features, bn.features)


def test_spec_build_factory_direct(graph_and_feats):
    """The one-factory entry point from the redesign:
    DataPlaneSpec.preset("gids").build(graph, features)."""
    g, feats = graph_and_feats
    plane = DataPlaneSpec.preset("gids").build(g, feats)
    ids = np.unique(np.random.default_rng(1).integers(0, g.num_nodes, 200))
    rows, report = plane.store.gather(ids)
    np.testing.assert_array_equal(rows, feats[ids])
    assert report.n_requests == len(ids)
    assert report.tier_names == ("hbm-cache", "host-cbuf", "storage")
    assert plane.min_lookahead == 8            # gids floors at window depth


def test_mmap_plane_is_synchronous(graph_and_feats):
    g, feats = graph_and_feats
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=64, fanouts=(3,), data_plane="mmap"))
    b = dl.next_batch()
    assert b.merge_depth == 1
    assert b.report.n_storage == b.report.n_requests


def test_custom_preset_registration(graph_and_feats):
    g, feats = graph_and_feats
    name = "test-hot-host"
    if name not in DataPlaneSpec.names():
        DataPlaneSpec.register(DataPlaneSpec(
            name=name,
            tiers=(tier("constant_buffer", fraction=0.5),
                   tier("storage"))))
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=128, fanouts=(4,), data_plane=name))
    b = dl.next_batch()
    assert b.report.n_hbm_hits == 0            # no device tier in the stack
    assert b.report.n_host_hits > 0
    assert b.report.n_host_hits + b.report.n_storage == b.report.n_requests
    assert isinstance(DataPlaneSpec.preset(name).tiers[0], TierSpec)
    with pytest.raises(ValueError):
        DataPlaneSpec.register(DataPlaneSpec(name=name, tiers=()))
    with pytest.raises(KeyError, match="unknown data-plane preset"):
        DataPlaneSpec.preset("no-such-plane")


# -- report semantics ----------------------------------------------------------

@pytest.mark.parametrize("mode", ["gids", "bam", "mmap", "gids-merged",
                                  "gids-sharded"])
def test_mode_shim_emits_deprecation_and_resolves(mode):
    """The PR 1 shim's contract, pinned directly: LoaderConfig(mode=...)
    warns exactly once and resolves to the preset of the same name."""
    with pytest.warns(DeprecationWarning, match="data_plane"):
        cfg = LoaderConfig(mode=mode)
    assert cfg.data_plane == mode
    assert DataPlaneSpec.resolve(cfg.data_plane).name == mode
    assert cfg.mode == mode                    # read shim agrees


def test_mode_shim_is_readable_and_typoed_knobs_rejected(graph_and_feats):
    import dataclasses

    g, feats = graph_and_feats
    with pytest.warns(DeprecationWarning):
        cfg = LoaderConfig(mode="bam")
    assert cfg.mode == "bam"                   # read side of the shim
    cfg2 = LoaderConfig(data_plane=DataPlaneSpec.preset("gids"))
    assert cfg2.mode == "gids"                 # spec resolves to its name
    with pytest.raises(AttributeError):
        cfg.no_such_attr
    with pytest.raises(TypeError, match="unknown build override"):
        DataPlaneSpec.preset("gids").build(g, feats, cache_line=64)

    # dataclasses.replace re-feeds the shimmed mode read through __init__;
    # an explicit data_plane must win and spec objects must survive intact
    assert dataclasses.replace(
        LoaderConfig(data_plane="gids"), data_plane="bam").data_plane == "bam"
    spec = DataPlaneSpec.preset("gids").with_(name="replace-keeps-spec")
    kept = dataclasses.replace(LoaderConfig(data_plane=spec), batch_size=64)
    assert kept.data_plane is spec
    # explicit new API beats the deprecated kwarg when both are given
    # (no warning: this is exactly the pair replace() feeds on every call)
    assert LoaderConfig(data_plane="gids", mode="mmap").data_plane == "gids"


def test_report_bytes_per_row_and_alias_removed(graph_and_feats):
    g, feats = graph_and_feats
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=64, fanouts=(3,), data_plane="gids", cache_lines=1024,
        window_depth=2))
    r = dl.next_batch().report
    assert r.bytes_per_row == feats.shape[1] * feats.dtype.itemsize
    # the deprecated feat_bytes alias (PR 1) completed its cycle: nothing
    # imported it, so it is gone rather than warning forever
    with pytest.raises(AttributeError):
        r.feat_bytes


# -- plan -> Pallas kernel wiring ----------------------------------------------

def test_kernel_slots_feed_tiered_gather(graph_and_feats):
    import jax.numpy as jnp
    from repro.kernels import ops

    g, feats = graph_and_feats
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=32, fanouts=(3,), data_plane="gids", cache_lines=4096,
        window_depth=2, cbuf_fraction=0.0))
    for _ in range(3):                         # warm the cache for real hits
        b = dl.next_batch()
    plan = dl.store.last_plan
    slots = plan.kernel_slots(0)
    assert (slots[plan.mask(0)] >= 0).all()    # hits carry a cache line
    assert (slots[~plan.mask(0)] == -1).all()  # everything else is staged
    cache_rows = dl.store.device_rows(0)
    staged = feats[plan.node_ids]
    out = ops.tiered_gather(jnp.asarray(slots, jnp.int32),
                            jnp.asarray(cache_rows), jnp.asarray(staged))
    np.testing.assert_allclose(np.asarray(out), feats[plan.node_ids])
    assert b.report.n_hbm_hits == int(plan.mask(0).sum())


def test_device_store_tier_plane(graph_and_feats):
    """The fully-jittable HBM tier composes like any other tier."""
    g, feats = graph_and_feats
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=16, fanouts=(2,), data_plane="gids-device",
        cache_lines=512, window_depth=0, cbuf_fraction=0.0))
    b1 = dl.next_batch()
    np.testing.assert_array_equal(b1.features, feats[b1.blocks.all_nodes])
    assert b1.report.n_hbm_hits + b1.report.n_storage == b1.report.n_requests
    # the tier's own gathered rows match the backstop's
    dtier = dl.store.tiers[0]
    np.testing.assert_allclose(dtier.last_rows, feats[b1.blocks.all_nodes],
                               rtol=1e-6)
    # the kernel feed works for the jittable tier too: resident slots point
    # at device rows holding the right features (warm until hub nodes repeat)
    hbm = np.zeros(0, bool)
    for _ in range(6):
        dl.next_batch()
        plan = dl.store.last_plan
        slots = plan.kernel_slots(0)
        hbm = slots >= 0
        if hbm.any():
            break
    assert hbm.any()
    rows = dl.store.device_rows(0)
    np.testing.assert_allclose(rows[slots[hbm]], feats[plan.node_ids[hbm]],
                               rtol=1e-6)


def test_unknown_latency_class_rejected():
    feats = np.zeros((50, 4), np.float32)

    class NvmeTier(StorageTier):
        latency_class = "nvme"

    with pytest.raises(ValueError, match="latency_class"):
        TieredFeatureStore([NvmeTier(feats), StorageTier(feats)])


# -- checkpoint-resume telemetry reset -----------------------------------------

def test_resume_resets_telemetry_bit_reproducible(graph_and_feats):
    g, feats = graph_and_feats
    mk = lambda: GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=64, fanouts=(4,), data_plane="gids", cache_lines=1024,
        window_depth=2, seed=11))
    a = mk()
    for _ in range(6):
        a.next_batch()
    assert a.accumulator.redirect_rate > 0
    st = a.state_dict()

    a.load_state_dict(st)                      # resume in place
    assert a.accumulator.redirect_rate == 0.0
    assert a.store.cache.stats.accesses == 0   # tier state dropped too

    b = mk()                                   # resume on a fresh loader
    b.load_state_dict(st)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba.blocks.seeds, bb.blocks.seeds)
        assert ba.report == bb.report
        assert ba.prep_time_s == bb.prep_time_s


# -- KV slot pool (serve engine's tier) ----------------------------------------

def test_kv_slot_tier_recycling():
    (kv,) = DataPlaneSpec.preset("serve-kv").build_stack(
        slots=2, bytes_per_slot=1024)
    assert isinstance(kv, KVSlotTier)
    assert kv.capacity_bytes == 2048
    s0, s1 = kv.acquire(10), kv.acquire(11)
    assert {s0, s1} == {0, 1}
    assert kv.acquire(12) is None              # pool full
    assert kv.acquire(10) == s0                # idempotent for the holder
    np.testing.assert_array_equal(kv.probe(np.array([10, 11, 12])),
                                  [True, True, False])
    assert kv.release(10) == s0
    assert kv.acquire(12) == s0                # recycled
    assert kv.occupancy == 1.0
